"""Online adaptation: drifted-stream recovery + classifier-update cost.

The two claims behind the online-learning runtime (ISSUE 3):

* ``retile-vs-precompute`` — installing an updated classifier into the
  scoring kernel is a jitted device-side gather
  (:func:`repro.kernels.ops.retile_classes` against a cached
  :class:`~repro.kernels.sliding_scores.ScoreGeometry`), far cheaper than
  the full host-side ``precompute_tiles`` (which rebuilds the slabs, the
  rotation index and the bias tiles nobody changed). ``--check`` enforces
  ``retile <= precompute / 2``.

* ``drift-recovery`` — on a synthetic stream whose background gain, noise
  sigma and object intensity drift away from the training distribution
  (:func:`repro.sensing.synthetic.make_drift_stream`), an adaptive runner
  (label feedback, the paper's similarity-scaled perceptron rule applied
  to each frame's top-scoring fragment) recovers frame-score AUC on the
  drifted half of the stream, while the frozen model degrades. ``--check``
  enforces ``adaptive late-AUC >= frozen late-AUC``.

Also reported (not enforced): the confidence-gated pseudo-label mode and
the wall-clock overhead of adaptation per processed frame.

Everything is seeded; on CPU the numbers are deterministic.

Run:  PYTHONPATH=src python benchmarks/adaptation.py [--check]
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fragment_model as fm, hypersense, metrics
from repro.core.encoding import make_perm_base_rows
from repro.core.online import AdaptConfig
from repro.core.sensor_control import ControllerConfig
from repro.kernels import ops
from repro.sensing import fragments, synthetic
from repro.sensing.stream import StreamRunner

# CPU-tractable scale; the drift scenario is chosen so the frozen model
# genuinely degrades (late AUC ~0.73 here) and label feedback measurably
# recovers (~0.78) — deterministic under the fixed seeds.
FRAME = 32
FRAG = 8
STRIDE = 4
DIM = 1024
N_STREAM = 200
CHUNK = 16
LR = 2.0

# retile timing at deployment-like scale (bigger model than the AUC demo:
# the precompute/retile gap is the per-model-size claim)
T_FRAG, T_DIM, T_W, T_BLOCK = 16, 4096, 128, 512


def _best(fn, reps: int) -> float:
    fn()  # warmup: jit compile
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def time_retile(reps: int = 5) -> dict:
    """Classifier-update cost: full host precompute vs device retile."""
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), T_FRAG, T_DIM)
    chvs = jax.random.normal(jax.random.PRNGKey(1), (2, T_DIM))
    geom = ops.precompute_geometry(B0, b, W=T_W, w=T_FRAG, stride=8,
                                   block_d=T_BLOCK)
    t_pre = _best(lambda: jax.block_until_ready(
        ops.precompute_tiles(B0, b, chvs, W=T_W, w=T_FRAG, stride=8,
                             block_d=T_BLOCK)), reps)
    t_ret = _best(lambda: jax.block_until_ready(
        ops.retile_classes(geom, chvs)), reps)
    return {"precompute_ms": t_pre * 1e3, "retile_ms": t_ret * 1e3,
            "speedup": t_pre / t_ret}


def _train_gate(cfg):
    """Fragment model on the *clean* (pre-drift) distribution."""
    frames, masks, _ = synthetic.make_dataset(jax.random.PRNGKey(0), 60,
                                              cfg)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames), np.asarray(masks), h=FRAG, w=FRAG,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=DIM, epochs=8)
    B0 = model.B.reshape(FRAG, FRAG, -1)[:, 0, :]
    return hypersense.from_fragment_model(model, B0, h=FRAG, w=FRAG,
                                          stride=STRIDE, t_detection=1)


def _auc(scores, labels) -> float:
    fpr, tpr, _ = metrics.roc_curve(scores, labels)
    return float(metrics.auc(fpr, tpr))


def drift_recovery(backend: str = "jnp") -> dict:
    """Frozen vs adaptive frame-score AUC on the drifted half."""
    cfg = synthetic.RadarConfig(height=FRAME, width=FRAME)
    hs = _train_gate(cfg)
    drift = synthetic.DriftConfig(background_gain=(0.0, 0.7),
                                  noise_sigma=(0.12, 0.3),
                                  object_intensity=(0.8, 0.3))
    stream, labels = synthetic.make_drift_stream(
        jax.random.PRNGKey(3), N_STREAM, cfg, drift, event_prob=0.06,
        event_len=10)
    labels = np.asarray(labels)
    half = N_STREAM // 2
    control = ControllerConfig(hold_frames=2)

    def timed(runner, feed):
        runner.process(stream[:CHUNK],
                       labels=None if feed is None else feed[:CHUNK])
        runner.reset()                       # warmup: jit + tile precompute
        t0 = time.perf_counter()
        out = runner.process(stream, labels=feed)
        return out, time.perf_counter() - t0

    frozen = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend)
    (s_frozen, _, _), t_frozen = timed(frozen, None)

    ada = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend,
                       adapt=AdaptConfig(mode="label", lr=LR))
    (s_label, _, _), t_label = timed(ada, labels)

    pseudo = StreamRunner(hs, control, chunk_size=CHUNK, backend=backend,
                          adapt=AdaptConfig(mode="pseudo", lr=0.5,
                                            confidence=0.02))
    (s_pseudo, _, _), _ = timed(pseudo, None)

    return {
        "frozen_auc_late": _auc(s_frozen[half:], labels[half:]),
        "label_auc_late": _auc(s_label[half:], labels[half:]),
        "pseudo_auc_late": _auc(s_pseudo[half:], labels[half:]),
        "frozen_auc_early": _auc(s_frozen[:half], labels[:half]),
        "adapt_overhead_ms_per_frame":
            (t_label - t_frozen) / N_STREAM * 1e3,
        "backend": backend,
    }


def run(backend: str = "jnp", reps: int = 5) -> list[dict]:
    """Benchmark-driver entry point (``python -m benchmarks.run``)."""
    t = time_retile(reps)
    r = drift_recovery(backend)
    return [
        {"name": "adaptation/retile",
         "precompute_ms": f"{t['precompute_ms']:.2f}",
         "retile_ms": f"{t['retile_ms']:.2f}",
         "speedup": f"{t['speedup']:.1f}x"},
        {"name": "adaptation/drift",
         "frozen_early": f"{r['frozen_auc_early']:.4f}",
         "frozen_late": f"{r['frozen_auc_late']:.4f}",
         "label_late": f"{r['label_auc_late']:.4f}",
         "pseudo_late": f"{r['pseudo_auc_late']:.4f}",
         "overhead_ms_per_frame":
             f"{r['adapt_overhead_ms_per_frame']:.3f}",
         "backend": r["backend"]},
    ]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--reps", type=int, default=5)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless retile <= precompute/2 AND "
                         "adaptive late-AUC >= frozen late-AUC under "
                         "drift (the online-learning claims)")
    args = ap.parse_args()

    rows = run(args.backend, args.reps)
    vals = {}
    for row in rows:
        name = row.pop("name")
        vals[name] = dict(row)
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))

    if args.check:
        t = vals["adaptation/retile"]
        r = vals["adaptation/drift"]
        if float(t["retile_ms"]) > float(t["precompute_ms"]) / 2:
            raise SystemExit(
                f"REGRESSION: retile_classes {t['retile_ms']} ms not "
                f"<= precompute_tiles/2 ({t['precompute_ms']} ms / 2)")
        if float(r["label_late"]) < float(r["frozen_late"]):
            raise SystemExit(
                f"REGRESSION: adaptive late-AUC {r['label_late']} < "
                f"frozen late-AUC {r['frozen_late']} under drift")
        print("adaptation/check,ok=True")


if __name__ == "__main__":
    main()
