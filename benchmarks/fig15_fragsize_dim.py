"""Paper Figs. 14-15: fragment size x dimensionality -> max TPR @ target FPR.

Claims reproduced:
  * at the LOWEST target FPR, larger fragment sizes win;
  * as target FPR rises, smaller fragments catch up/overtake (trend);
  * higher dimensionality helps (Fig. 15 rows).
"""

from __future__ import annotations


from benchmarks import common
from repro.core import metrics

SIZES = [8, 16, 24]
DIMS = [2048, 8192]
TARGET_FPRS = [0.05, 0.1, 0.2, 0.3]


def run() -> list[dict]:
    rows = []
    for dim in DIMS:
        for size in SIZES:
            _, _, scores, labels = common.hdc_model(size, dim)
            fpr, tpr, _ = metrics.roc_curve(scores, labels)
            entry = {"name": f"fig15/frag{size}_dim{dim}"}
            for t in TARGET_FPRS:
                entry[f"tpr@fpr{t}"] = round(
                    metrics.tpr_at_fpr(fpr, tpr, t), 4)
            rows.append(entry)
    return rows


if __name__ == "__main__":
    for row in run():
        print(row)
