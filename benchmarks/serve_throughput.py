"""Fleet serving throughput: async double-buffered service vs sync runner.

The serving-layer measurement (``repro.launch.serve.FleetService``):
sustained frames/sec and p99 per-chunk latency of the always-on service
against the synchronous ``FleetRunner`` baseline on the SAME trace.

* ``sync-runner`` — ``FleetRunner.process`` per tick with the results
  pulled to host every tick (``np.asarray`` after every chunk): host→device
  transfer, kernel, and device→host readback strictly serialized;
* ``async-serve`` — ``FleetService.dispatch``/``collect`` with
  ``max_inflight=2``: the host assembles + transfers tick ``t+1`` while
  the device still computes tick ``t`` (JAX async dispatch), the carried
  state is donated, and collection only ever blocks on the oldest
  in-flight tick.

Both paths are bitwise-identical per stream (``tests/test_serve.py``
pins it; ``--check`` re-verifies on this trace). The second phase runs a
scripted attach/detach **churn** schedule through the slot pool and
asserts the step never recompiles (fixed shapes — churn only flips
``slot_mask`` bits); the third snapshots mid-trace through the async
checkpointer and verifies a restored service finishes the trace bitwise
identical to the uninterrupted one.

Run:  PYTHONPATH=src python benchmarks/serve_throughput.py [--check]
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.core import hypersense
from repro.core.encoding import make_perm_base_rows
from repro.core.sensor_control import ControllerConfig
from repro.launch.serve import FleetService
from repro.sensing.fleet import FleetRunner

# CPU-tractable scale. Small frames/D keep the per-tick device time in
# the same regime as the per-tick host time (dict assembly, transfers,
# python dispatch) — the serving overlap being measured is host-vs-device
# pipelining, and at compute-dominated scales the ratio degenerates to
# 1.0 on any backend (both paths just wait on the same kernels). The
# async/sync *ratio* is the claim; on accelerators the host fraction is
# larger still (real decode/assembly per arrival), widening the gap.
SLOTS = 4
TICKS = 16           # timed ticks per pass
CHUNK = 4
FRAME = 16
FRAG = 8
STRIDE = 8
DIM = 128
BLOCK_D = 128
REPS = 5
CHURN_TICKS = 24     # churn phase (jnp backend) schedule length


def _make_model(dim: int = DIM, frag: int = FRAG, stride: int = STRIDE):
    B0, b = make_perm_base_rows(jax.random.PRNGKey(0), frag, dim)
    C = jax.random.normal(jax.random.PRNGKey(1), (2, dim))
    return hypersense.HyperSenseModel(C, B0, b, frag, frag, stride,
                                      t_score=0.0, t_detection=2)


def _trace(slots: int, ticks: int, chunk: int, frame: int) -> np.ndarray:
    return np.asarray(jax.random.uniform(
        jax.random.PRNGKey(2), (slots, ticks * chunk, frame, frame)),
        np.float32)


def _service(model, config, slots: int, chunk: int,
             backend: str, **kw) -> FleetService:
    return FleetService(model, config, n_slots=slots, chunk_size=chunk,
                        backend=backend, block_d=BLOCK_D, **kw)


def run(slots: int = SLOTS, ticks: int = TICKS, chunk: int = CHUNK,
        frame: int = FRAME, backend: str = "pallas", reps: int = REPS,
        check: bool = False):
    model = _make_model()
    config = ControllerConfig(hold_frames=3)
    trace = _trace(slots, ticks, chunk, frame)
    total = slots * ticks * chunk
    rows = []

    # --- phase 1: steady-state fps + latency, async vs sync -------------
    # Construct + warm both paths once, then time the tick loop alone:
    # the serving claim is the sustained loop, not cold start.
    runner = FleetRunner(model, config, chunk_size=chunk,
                         backend=backend, block_d=BLOCK_D)
    svc = _service(model, config, slots, chunk, backend)
    for i in range(slots):
        svc.attach(i)
    runner.process(trace[:, :chunk])                   # warmup: jit+tiles
    svc.dispatch({i: trace[i, :chunk] for i in range(slots)})
    svc.flush()

    def sync_pass():
        # per-tick arrival + host-resident results every tick = the
        # serving contract, minus the pipeline
        for t in range(ticks):
            runner.process(trace[:, t * chunk:(t + 1) * chunk])

    def async_pass():
        # dispatch-only loop: dispatch's own back-pressure collects the
        # oldest tick once max_inflight are queued, keeping the pipeline
        # exactly max_inflight deep; flush() drains the tail
        for t in range(ticks):
            svc.dispatch({i: trace[i, t * chunk:(t + 1) * chunk]
                          for i in range(slots)})
        return [c.latency_s for c in svc.flush()]

    def best_of(fn):
        best, best_out = float("inf"), None
        for _ in range(reps):
            t0 = time.perf_counter()
            out = fn()
            dt = time.perf_counter() - t0
            if dt < best:
                best, best_out = dt, out
        return best, best_out

    dt_sync, _ = best_of(sync_pass)
    dt_async, lat = best_of(async_pass)
    fps_sync = total / dt_sync
    fps_async = total / dt_async
    p99 = float(np.percentile(np.asarray(lat) * 1e3, 99))
    rows.append({"name": "serve_throughput/sync-runner",
                 "frames_per_sec": f"{fps_sync:.1f}",
                 "ms_per_pass": f"{dt_sync * 1e3:.1f}",
                 "sensors": slots, "backend": backend})
    rows.append({"name": "serve_throughput/async-serve",
                 "frames_per_sec": f"{fps_async:.1f}",
                 "ms_per_pass": f"{dt_async * 1e3:.1f}",
                 "p99_chunk_latency_ms": f"{p99:.1f}",
                 "sensors": slots, "backend": backend})
    rows.append({"name": "serve_throughput/async_vs_sync_speedup",
                 "value": f"{fps_async / fps_sync:.2f}x",
                 "sensors": slots, "backend": backend})
    if check and fps_async < fps_sync:
        raise SystemExit(
            f"REGRESSION: async-serve {fps_async:.1f} fps < sync-runner "
            f"{fps_sync:.1f} fps at S={slots}")

    # --- phase 2: churn-free bitwise parity ------------------------------
    runner = FleetRunner(model, config, chunk_size=chunk, backend=backend,
                         block_d=BLOCK_D)
    s_ref, f_ref, g_ref = runner.process(trace)
    svc = _service(model, config, slots, chunk, backend)
    for i in range(slots):
        svc.attach(i)
    got = {i: [] for i in range(slots)}
    for t in range(ticks):
        svc.dispatch({i: trace[i, t * chunk:(t + 1) * chunk]
                      for i in range(slots)})
    for ch in svc.flush():
        for sid, out in ch.outputs.items():
            got[sid].append(out)
    bitwise = all(
        np.array_equal(np.concatenate([o[j] for o in got[i]]), ref[i])
        for i in range(slots)
        for j, ref in enumerate((s_ref, f_ref, g_ref)))
    rows.append({"name": "serve_throughput/churn_free_bitwise",
                 "value": str(bitwise).lower(), "backend": backend})
    if check and not bitwise:
        raise SystemExit("REGRESSION: churn-free FleetService outputs "
                         "differ from the synchronous FleetRunner")

    # --- phase 3: slot churn, zero recompiles (jnp: longer schedule) ----
    churn_rows = _churn_phase(model, config, slots, chunk, frame, check)
    rows.extend(churn_rows)

    # --- phase 4: checkpoint restore bitwise ----------------------------
    rows.extend(_ckpt_phase(model, config, slots, chunk, trace, check))
    return rows


def _churn_phase(model, config, slots, chunk, frame, check):
    """Scripted attach/detach schedule: throughput under churn + the
    zero-recompile witness (``FleetService.compile_count`` deltas)."""
    trace = _trace(slots + 2, CHURN_TICKS, chunk, frame)
    svc = _service(model, config, slots, chunk, "jnp")
    svc.attach(0)
    svc.dispatch({0: trace[0, 0:chunk]})   # warmup tick fixes the trace
    svc.flush()
    c0 = svc.compile_count()
    live = {0}
    n_frames = chunk
    lat = []
    t0 = time.perf_counter()
    for t in range(1, CHURN_TICKS):
        if t % 3 == 0 and len(live) < slots:        # arrivals...
            nxt = max(live) + 1 if live else 0
            if nxt < trace.shape[0]:
                svc.attach(nxt)
                live.add(nxt)
        if t % 5 == 0 and len(live) > 1:            # ...and departures
            gone = min(live)
            svc.detach(gone)
            live.discard(gone)
        arr = {i: trace[i, t * chunk:(t + 1) * chunk] for i in live
               if t % 7 != 0 or i % 2 == 0}          # ragged arrival
        svc.dispatch(arr)
        n_frames += chunk * len(arr)
    lat.extend(c.latency_s for c in svc.flush())
    dt = time.perf_counter() - t0
    recompiles = svc.compile_count() - c0
    p99 = float(np.percentile(np.asarray(lat) * 1e3, 99)) if lat else 0.0
    rows = [{"name": "serve_throughput/churn",
             "frames_per_sec": f"{n_frames / dt:.1f}",
             "p99_chunk_latency_ms": f"{p99:.1f}",
             "ticks": CHURN_TICKS, "recompiles_after_warmup": recompiles,
             "backend": "jnp"}]
    if check and recompiles != 0:
        raise SystemExit(
            f"REGRESSION: slot churn triggered {recompiles} recompiles "
            "(the pool contract is zero — churn only flips slot_mask "
            "bits)")
    return rows


def _ckpt_phase(model, config, slots, chunk, trace, check):
    """Mid-trace async snapshot; a restored service must finish the
    trace bitwise-identical to the uninterrupted one."""
    import tempfile
    ticks = trace.shape[1] // chunk
    cut = ticks // 2
    with tempfile.TemporaryDirectory() as td:
        def fresh():
            return _service(model, config, slots, chunk, "jnp",
                            ckpt_dir=td)

        def play(svc, lo, hi):
            out = {}
            for t in range(lo, hi):
                svc.dispatch({i: trace[i, t * chunk:(t + 1) * chunk]
                              for i in range(slots)})
            for ch in svc.flush():
                for sid, o in ch.outputs.items():
                    out.setdefault(sid, []).append(o)
            return out

        svc = fresh()
        for i in range(slots):
            svc.attach(i)
        play(svc, 0, cut)
        svc.checkpoint()
        svc.wait_ckpt()
        ref = play(svc, cut, ticks)         # uninterrupted continuation

        svc2 = fresh()
        svc2.restore()
        got = play(svc2, cut, ticks)        # killed-and-resumed
    bitwise = all(
        np.array_equal(a, b)
        for sid in ref
        for ra, ga in zip(ref[sid], got[sid])
        for a, b in zip(ra, ga))
    rows = [{"name": "serve_throughput/ckpt_restore_bitwise",
             "value": str(bitwise).lower(), "ticks_before_snapshot": cut,
             "backend": "jnp"}]
    if check and not bitwise:
        raise SystemExit("REGRESSION: restored FleetService diverged "
                         "from the uninterrupted run")
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--slots", type=int, default=SLOTS)
    ap.add_argument("--ticks", type=int, default=TICKS)
    ap.add_argument("--chunk", type=int, default=CHUNK)
    ap.add_argument("--frame-size", type=int, default=FRAME)
    ap.add_argument("--backend", default="pallas",
                    choices=["pallas", "jnp"])
    ap.add_argument("--reps", type=int, default=REPS)
    ap.add_argument("--check", action="store_true",
                    help="exit nonzero unless async-serve >= sync-runner "
                         "frames/sec, churn-free outputs are bitwise the "
                         "FleetRunner's, churn causes zero recompiles, "
                         "and checkpoint restore is bitwise")
    try:
        from benchmarks import common   # -m benchmarks.run / repo root
    except ImportError:
        import common                   # standalone: script dir on path
    common.add_json_arg(ap)
    args = ap.parse_args()
    rows = run(args.slots, args.ticks, args.chunk, args.frame_size,
               args.backend, args.reps, check=args.check)
    if args.json:
        print("json ->", common.write_json(args.json, "serve_throughput",
                                           rows))
    for row in rows:
        name = row.pop("name")
        print(name + "," + ",".join(f"{k}={v}" for k, v in row.items()))


if __name__ == "__main__":
    main()
