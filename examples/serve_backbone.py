"""Serve a small model with batched requests (deliverable b).

Batched greedy decoding with KV cache through the production decode path.

Run:  PYTHONPATH=src python examples/serve_backbone.py
"""

import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.decode import greedy_decode
from repro.models import lm


def main() -> None:
    cfg = configs.get_smoke("internlm2-1.8b").replace(
        n_layers=4, d_model=128, n_heads=4, kv_heads=2, d_ff=512)
    model = lm.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    batch, prompt_len, gen = 4, 8, 24
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (batch, prompt_len), 0, cfg.vocab)
    t0 = time.time()
    toks = greedy_decode(model, params, prompts, gen,
                         max_seq=prompt_len + gen)
    dt = time.time() - t0
    print(f"served {batch} requests, {gen} new tokens each, in {dt:.1f}s")
    print("first request tokens:", toks[0].tolist())

    # determinism check: same prompts -> same generation
    toks2 = greedy_decode(model, params, prompts, gen,
                          max_seq=prompt_len + gen)
    assert (toks == toks2).all(), "decode must be deterministic"
    print("determinism check passed")


if __name__ == "__main__":
    main()
