"""Quickstart: train a HyperSense fragment model and score a frame.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fragment_model as fm
from repro.core import hypersense, metrics
from repro.core.encoding import encode_fragments
from repro.sensing import adc, fragments, synthetic


def main() -> None:
    key = jax.random.PRNGKey(0)

    # 1. sense: synthetic radar frames through the low-precision ADC path
    cfg = synthetic.RadarConfig(height=64, width=64)
    frames, masks, labels = synthetic.make_dataset(key, 80, cfg)
    frames_lp = adc.quantize(frames, bits=4)

    # 2. fragment dataset (balanced positives/negatives)
    frags, flabels = fragments.sample_fragments(
        np.asarray(frames_lp), np.asarray(masks), h=16, w=16,
        per_frame=2, seed=0)
    n = len(frags)
    tr, te = slice(0, int(n * 0.8)), slice(int(n * 0.8), n)

    # 3. train the HDC Fragment model (bundling + retraining)
    model, info = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frags[tr]),
        jnp.asarray(flabels[tr]), dim=4096, epochs=10)
    print("retraining val accuracy:", [round(a, 3)
                                       for a in info["val_accuracy"]])

    # 4. fragment-level ROC
    hv = encode_fragments(jnp.asarray(frags[te]), model.B, model.b)
    scores = fm.positive_score(model.class_hvs, hv)
    fpr, tpr, _ = metrics.roc_curve(np.asarray(scores), flabels[te])
    print(f"fragment AUC: {metrics.auc(fpr, tpr):.3f}")

    # 5. frame-level HyperSense detection (sliding window, reuse encoder)
    B0 = model.B.reshape(16, 16, -1)[:, 0, :]
    hs = hypersense.from_fragment_model(model, B0, h=16, w=16, stride=8,
                                        t_score=0.0, t_detection=0)
    decisions = hypersense.detect_batch(hs, frames_lp[:16])
    print("frame decisions:", np.asarray(decisions).astype(int))
    print("frame labels:   ", np.asarray(labels[:16]))


if __name__ == "__main__":
    main()
