"""The paper's full loop: HDC gate → HP capture → backbone detector.

A closed-loop StreamRunner gates a sparse-event radar stream, its
high-precision burst drains feed a CascadeService backbone, and the
capture log bills the whole system against an always-on detector.

Run:  PYTHONPATH=src python examples/gated_cascade.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core import encoding, hypersense
from repro.core.sensor_control import CaptureConfig, ControllerConfig
from repro.launch import steps
from repro.launch.cascade import CascadeService
from repro.sensing import synthetic
from repro.sensing.stream import StreamRunner

FRAME, CHUNK, BATCH = 32, 16, 8


def main() -> None:
    # a tiny gate (untrained weights are fine for the plumbing demo);
    # threshold at the open-loop score q75 so only score peaks fire
    # (closed-loop decimation skips idle frames, thinning high scores)
    B0, b = encoding.make_perm_base_rows(jax.random.PRNGKey(1), 8, 256)
    gate = hypersense.HyperSenseModel(
        jax.random.normal(jax.random.PRNGKey(2), (2, 256)), B0, b,
        h=8, w=8, stride=4, t_score=0.0, t_detection=1)
    stream, _ = synthetic.make_drift_stream(
        jax.random.PRNGKey(3), 8 * CHUNK,
        synthetic.RadarConfig(height=FRAME, width=FRAME),
        event_prob=0.03, event_len=10)
    stream = np.asarray(stream)
    scores = hypersense.frame_scores_batch(gate, stream, 0,
                                           sequential=True)
    gate = gate._replace(t_score=float(np.quantile(scores, 0.75)))
    runner = StreamRunner(gate,
                          ControllerConfig(base_rate_hz=10.0,
                                           active_rate_hz=30.0,
                                           hold_frames=4),
                          chunk_size=CHUNK,
                          control=CaptureConfig(hp_bits=12))

    # the downstream detector: smoke embeds-in backbone + patch embedder
    cfg = configs.get_smoke("hubert-xlarge")
    params = steps.init_detector_params(jax.random.PRNGKey(7), cfg,
                                        frame_hw=(FRAME, FRAME), patch=8)
    casc = CascadeService(params, cfg, batch_size=BATCH,
                          frame_hw=(FRAME, FRAME))

    for t in range(0, len(stream), CHUNK):
        runner.process(stream[t:t + CHUNK])
        casc.pump(runner)                 # ragged drain -> fixed batches
    for batch in casc.flush():
        for i, logit in zip(batch.frame_idx, batch.logits):
            label = int(jnp.argmax(jnp.asarray(logit)))
            print(f"frame {int(i):4d}  detector class {label}  "
                  f"logits {np.round(logit, 3)}")

    log = runner.capture_log
    e = casc.system_energy(log)
    duty = float(np.asarray(log.gated, bool).mean())
    print(f"\ngate duty cycle      {duty:.3f}")
    print(f"backbone compiles    {casc.compile_count()} "
          f"(ragged drains, fixed shapes)")
    print(f"cascade   J/frame    {e['cascade'].total:.4f}")
    print(f"always-on J/frame    {e['always_on'].total:.4f}  "
          f"(saving {1 - e['cascade'].total / e['always_on'].total:.1%})")


if __name__ == "__main__":
    main()
