"""Fault-tolerance demo: train, 'crash', resume; elastic re-shard restore.

Simulates the production contract (DESIGN.md §5):
  1. train 6 steps with async checkpointing every 3
  2. "node failure" — a fresh process state (new model object)
  3. relaunch resumes from the latest valid checkpoint, continuing the
     exactly-once data stream
  4. elastic restore: the same checkpoint re-shards onto a different mesh

Run:  PYTHONPATH=src python examples/fault_tolerance_demo.py
"""

import shutil
import tempfile

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.ckpt import checkpoint as ckpt
from repro.models import lm
from repro.train import loop as train_loop


def main() -> None:
    ckpt_dir = tempfile.mkdtemp(prefix="repro_ft_")
    cfg = configs.get_smoke("internlm2-1.8b")
    model = lm.build(cfg)

    # --- phase 1: train + checkpoint ---
    tc = train_loop.TrainConfig(steps=6, ckpt_every=3, log_every=3,
                                ckpt_dir=ckpt_dir, lr=1e-3)
    data = train_loop.synthetic_lm_data(cfg, batch=2, seq=16)
    train_loop.train(model, data, tc)
    print(f"[demo] latest checkpoint: step {ckpt.latest_step(ckpt_dir)}")

    # --- phase 2: 'crash' + relaunch with more steps ---
    print("[demo] simulating node failure + relaunch ...")
    model2 = lm.build(cfg)                      # fresh process state
    tc2 = train_loop.TrainConfig(steps=10, ckpt_every=3, log_every=2,
                                 ckpt_dir=ckpt_dir, lr=1e-3)
    data2 = train_loop.synthetic_lm_data(cfg, batch=2, seq=16,
                                         start_step=6)
    result = train_loop.train(model2, data2, tc2)
    assert result["step"] == 10
    print("[demo] resumed and finished at step 10")

    # --- phase 3: elastic restore onto a different mesh ---
    from repro.train import optim

    mesh = jax.make_mesh((1, 1), ("data", "model"))
    params = model.init(jax.random.PRNGKey(0))
    opt = optim.AdamW(lr=1e-3, weight_decay=0.1)
    like = (params, opt.init(params))
    shardings = jax.tree.map(lambda x: NamedSharding(mesh, P()), like)
    try:
        (p2, _), extra = ckpt.restore(ckpt_dir, like, shardings=shardings)
        assert next(iter(jax.tree.leaves(p2))).sharding == \
            NamedSharding(mesh, P())
        print(f"[demo] elastic restore ok (step {extra['step']}); "
              "same checkpoint loads on any mesh")
    finally:
        shutil.rmtree(ckpt_dir, ignore_errors=True)


if __name__ == "__main__":
    main()
