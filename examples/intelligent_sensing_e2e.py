"""End-to-end Intelligent Sensor Control (the paper's full pipeline).

sensor stream -> low-precision ADC -> HDC HyperSense gate -> high-precision
path + "cloud model" only when gated on -> energy accounting (Fig. 17).

Run:  PYTHONPATH=src python examples/intelligent_sensing_e2e.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, fragment_model as fm, hypersense, metrics
from repro.core.sensor_control import ControllerConfig
from repro.sensing import adc, fragments, synthetic
from repro.sensing.stream import simulate_stream_batched


def main() -> None:
    key = jax.random.PRNGKey(0)
    frag, dim, stride = 16, 2048, 8

    # --- train the gate on captured data --------------------------------
    cfg = synthetic.RadarConfig(height=64, width=64)
    frames, masks, _ = synthetic.make_dataset(key, 60, cfg)
    frames_lp = adc.quantize(frames, 4)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames_lp), np.asarray(masks), h=frag, w=frag,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=dim, epochs=10)
    B0 = model.B.reshape(frag, frag, -1)[:, 0, :]

    # --- pick the operating point for a target FPR ----------------------
    te_frames, te_masks, te_labels = synthetic.make_dataset(
        jax.random.PRNGKey(2), 24, cfg)
    te_lp = adc.quantize(te_frames, 4)
    hs = hypersense.from_fragment_model(model, B0, h=frag, w=frag,
                                        stride=stride)
    scores = np.asarray(hypersense.frame_scores_batch(hs, te_lp, 0,
                                                      sequential=True))
    fpr, tpr, thr = metrics.roc_curve(scores, np.asarray(te_labels))
    target_fpr = 0.1
    t_score = metrics.threshold_at_fpr(fpr, tpr, thr, target_fpr)
    print(f"operating point: FPR<={target_fpr} -> T_score={t_score:.4f} "
          f"TPR={metrics.tpr_at_fpr(fpr, tpr, target_fpr):.3f}")
    hs = hs._replace(t_score=float(t_score))

    # --- stream with infrequent events through the controller -----------
    # Chunked batched runtime: each 32-frame chunk is scored in one jitted
    # step (one kernel launch on the pallas backend) and gated through the
    # SensorController hysteresis — identical StreamStats to the
    # frame-at-a-time loop, at a fraction of the dispatches.
    stream, stream_labels = synthetic.make_stream(
        jax.random.PRNGKey(3), 150, cfg, event_prob=0.03, event_len=10)
    stream_lp = adc.quantize(stream, 4)

    stats = simulate_stream_batched(hs, stream_lp,
                                    np.asarray(stream_labels),
                                    ControllerConfig(hold_frames=3),
                                    chunk_size=32, backend="jnp")
    print(f"stream: duty cycle {stats.duty_cycle:.3f}, "
          f"missed positives {stats.missed_positive:.3f}, "
          f"false active {stats.false_active:.3f}")

    # --- energy accounting (paper Fig. 17 / Table III) -------------------
    params = energy.calibrate()
    conv = energy.conventional(params)
    p_obj = float(np.mean(stream_labels))
    ours = energy.hypersense(stats.false_active,
                             1.0 - stats.missed_positive, p_obj, params)
    s = energy.savings(ours, conv)
    print(f"p(object)={p_obj:.3f}: total energy saving "
          f"{s['total_saving']:.1%}, edge saving {s['edge_saving']:.1%}, "
          f"quality loss {stats.missed_positive:.2%}")
    print(f"(paper @FPR0.1: total 89.8%, edge 60.6%, QL 4.93%)")


if __name__ == "__main__":
    main()
