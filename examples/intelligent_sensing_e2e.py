"""End-to-end Intelligent Sensor Control (the paper's full pipeline).

sensor stream -> low-precision ADC -> HDC HyperSense gate -> high-precision
path + "cloud model" only when gated on -> energy accounting (Fig. 17).

Single-sensor by default; ``--sensors S`` runs the same trained gate over
S concurrent streams through the fleet runtime
(:mod:`repro.sensing.fleet`): every super-chunk is scored in one batched
step (one kernel launch on ``--backend pallas``), each stream keeps its
own controller hysteresis, and the energy account aggregates the fleet.
The ADC sits *inside* the runtime (``adc_bits=4``) — the gate scores the
cheap 4-bit capture while the raw high-precision frames stand in for what
the gated-on path would deliver.

Run:  PYTHONPATH=src python examples/intelligent_sensing_e2e.py [--sensors 4]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import energy, fragment_model as fm, hypersense, metrics
from repro.core.online import AdaptConfig
from repro.core.sensor_control import (CaptureConfig, ControllerConfig,
                                       decimation, stats_from)
from repro.sensing import adc, fragments, synthetic
from repro.sensing.fleet import simulate_fleet
from repro.sensing.stream import StreamRunner, simulate_stream_batched


def train_gate(key, cfg, frag, dim, stride):
    """Train the Fragment model on low-precision captures and pick the
    operating T_score for a target FPR (paper §III-C)."""
    frames, masks, _ = synthetic.make_dataset(key, 60, cfg)
    frames_lp = adc.quantize(frames, 4)
    frs, labs = fragments.sample_fragments(
        np.asarray(frames_lp), np.asarray(masks), h=frag, w=frag,
        per_frame=2, seed=0)
    model, _ = fm.train_fragment_model(
        jax.random.PRNGKey(1), jnp.asarray(frs), jnp.asarray(labs),
        dim=dim, epochs=10)
    B0 = model.B.reshape(frag, frag, -1)[:, 0, :]

    te_frames, _, te_labels = synthetic.make_dataset(
        jax.random.PRNGKey(2), 24, cfg)
    te_lp = adc.quantize(te_frames, 4)
    hs = hypersense.from_fragment_model(model, B0, h=frag, w=frag,
                                        stride=stride)
    scores = np.asarray(hypersense.frame_scores_batch(hs, te_lp, 0,
                                                      sequential=True))
    fpr, tpr, thr = metrics.roc_curve(scores, np.asarray(te_labels))
    target_fpr = 0.1
    t_score = metrics.threshold_at_fpr(fpr, tpr, thr, target_fpr)
    print(f"operating point: FPR<={target_fpr} -> T_score={t_score:.4f} "
          f"TPR={metrics.tpr_at_fpr(fpr, tpr, target_fpr):.3f}")
    return hs._replace(t_score=float(t_score))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sensors", type=int, default=1,
                    help="number of concurrent sensor streams (>1 uses "
                         "the fleet runtime)")
    ap.add_argument("--frames", type=int, default=150,
                    help="stream length per sensor")
    ap.add_argument("--backend", default="jnp", choices=["jnp", "pallas"])
    ap.add_argument("--drift", action="store_true",
                    help="drifting single-sensor stream: frozen gate vs "
                         "online adaptation (label feedback + pseudo)")
    ap.add_argument("--control", action="store_true",
                    help="close the capture loop: idle frames trickle at "
                         "base_rate_hz, gate bursts capture at "
                         "active_rate_hz + high precision; energy billed "
                         "from the capture log")
    args = ap.parse_args()

    if args.control:
        # --- gate-driven variable-rate/-precision capture ----------------
        cfg = synthetic.RadarConfig(height=32, width=32)
        hs = train_gate(jax.random.PRNGKey(0), cfg, 8, 1024, 4)
        rates = ControllerConfig(base_rate_hz=10, active_rate_hz=60,
                                 hold_frames=6)
        stream, labels = synthetic.make_stream(
            jax.random.PRNGKey(3), args.frames, cfg, event_prob=0.01,
            event_len=12)
        labels = np.asarray(labels)
        runner = StreamRunner(hs, rates, chunk_size=32,
                              backend=args.backend, adc_bits=4,
                              control=CaptureConfig(hp_bits=12))
        _, fired, gated = runner.process(stream)
        stats = stats_from(fired, gated, labels)
        log = runner.capture_log
        hp_idx, hp_frames = runner.drain_hp()
        print(f"closed loop (decim {decimation(rates)}): "
              f"LP-converted {int(log.sampled.sum())}/{len(stream)} "
              f"frames, duty {stats.duty_cycle:.3f}, "
              f"missed {stats.missed_positive:.3f}")
        print(f"HP deliverable: {len(hp_idx)} burst frames at "
              f"{log.hp_bits} bits (dropped {runner.hp_dropped})")
        ours = energy.from_capture_log(log)
        always = energy.hypersense_measured(stats.duty_cycle)
        conv = energy.conventional()
        print(f"energy/frame from capture log: {ours.total:.3f} J "
              f"(always-on LP estimate {always.total:.3f} J, "
              f"conventional {conv.total:.3f} J) -> "
              f"saving {1 - ours.total / conv.total:.1%}")
        return

    if args.drift:
        # --- online learning under distribution drift -------------------
        # CPU-tractable scale (three full runner passes over the stream)
        cfg = synthetic.RadarConfig(height=32, width=32)
        hs = train_gate(jax.random.PRNGKey(0), cfg, 8, 1024, 4)
        control = ControllerConfig(hold_frames=3)
        drift = synthetic.DriftConfig(background_gain=(0.0, 0.6),
                                      noise_sigma=(0.12, 0.28),
                                      object_intensity=(0.8, 0.35))
        stream, labels = synthetic.make_drift_stream(
            jax.random.PRNGKey(3), args.frames, cfg, drift,
            event_prob=0.05, event_len=10)
        labels = np.asarray(labels)
        half = len(labels) // 2

        def late_auc(scores):
            fpr, tpr, _ = metrics.roc_curve(scores[half:], labels[half:])
            return metrics.auc(fpr, tpr)

        frozen = StreamRunner(hs, control, chunk_size=32,
                              backend=args.backend)
        s_f, _, _ = frozen.process(stream)
        ada = StreamRunner(hs, control, chunk_size=32,
                           backend=args.backend,
                           adapt=AdaptConfig(mode="label", lr=2.0))
        s_a, _, _ = ada.process(stream, labels=labels)
        pseudo = StreamRunner(hs, control, chunk_size=32,
                              backend=args.backend,
                              adapt=AdaptConfig(mode="pseudo", lr=0.5,
                                                confidence=0.02))
        s_p, _, _ = pseudo.process(stream)
        print(f"drifted-half frame-score AUC: frozen {late_auc(s_f):.3f}, "
              f"label-feedback {late_auc(s_a):.3f}, "
              f"pseudo-label {late_auc(s_p):.3f}")
        return

    cfg = synthetic.RadarConfig(height=64, width=64)
    frag, dim, stride = 16, 2048, 8
    hs = train_gate(jax.random.PRNGKey(0), cfg, frag, dim, stride)
    control = ControllerConfig(hold_frames=3)

    if args.sensors <= 1:
        # --- single stream through the chunked runtime ------------------
        stream, stream_labels = synthetic.make_stream(
            jax.random.PRNGKey(3), args.frames, cfg, event_prob=0.03,
            event_len=10)
        stats = simulate_stream_batched(hs, stream,
                                        np.asarray(stream_labels),
                                        control, chunk_size=32,
                                        backend=args.backend, adc_bits=4)
        print(f"stream: duty cycle {stats.duty_cycle:.3f}, "
              f"missed positives {stats.missed_positive:.3f}, "
              f"false active {stats.false_active:.3f}")
        if not np.isfinite(stats.missed_positive):
            print("stream drew no object events (missed_positive is "
                  "undefined) — rerun with more --frames for the energy "
                  "account")
            return

        params = energy.calibrate()
        conv = energy.conventional(params)
        p_obj = float(np.mean(stream_labels))
        ours = energy.hypersense(stats.false_active,
                                 1.0 - stats.missed_positive, p_obj,
                                 params)
        s = energy.savings(ours, conv)
        print(f"p(object)={p_obj:.3f}: total energy saving "
              f"{s['total_saving']:.1%}, edge saving "
              f"{s['edge_saving']:.1%}, quality loss "
              f"{stats.missed_positive:.2%}")
        print("(paper @FPR0.1: total 89.8%, edge 60.6%, QL 4.93%)")
        return

    # --- sensor fleet: S streams, one batched runtime -------------------
    streams, labels = [], []
    for s in range(args.sensors):
        fr, lb = synthetic.make_stream(
            jax.random.fold_in(jax.random.PRNGKey(3), s), args.frames,
            cfg, event_prob=0.03, event_len=10)
        streams.append(fr)
        labels.append(np.asarray(lb))
    fleet_frames = jnp.stack(streams)
    fleet_labels = np.stack(labels)

    report = simulate_fleet(hs, fleet_frames, fleet_labels, control,
                            chunk_size=32, backend=args.backend,
                            adc_bits=4,
                            energy_params=energy.calibrate())
    for s, st in enumerate(report.stats):
        print(f"sensor {s}: duty {st.duty_cycle:.3f}, "
              f"missed {st.missed_positive:.3f}, "
              f"false-active {st.false_active:.3f}")
    print(f"fleet of {report.n_sensors} x {report.n_frames} frames: "
          f"mean duty cycle {report.duty_cycle:.3f}")
    print(f"fleet energy: {report.energy_total_j:.1f} J vs always-on "
          f"{report.baseline_total_j:.1f} J "
          f"-> total saving {report.total_saving:.1%}")


if __name__ == "__main__":
    main()
