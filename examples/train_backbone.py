"""Train a ~100M-param backbone for a few hundred steps (deliverable b).

Uses internlm2-1.8b's family at reduced width (~100M params) with the
production train loop (checkpointing, resume, preemption handler).

Run:  PYTHONPATH=src python examples/train_backbone.py [--steps 200]
"""

import argparse

from repro import configs
from repro.models import common, lm
from repro.train import loop as train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M-param dense config (internlm2 family, narrowed)
    cfg = configs.get_config("internlm2-1.8b").replace(
        n_layers=8, d_model=768, n_heads=12, kv_heads=6, d_ff=2048,
        vocab=32000, compute_dtype="float32", remat="none")
    model = lm.build(cfg)
    n = common.spec_param_count(model.spec())
    print(f"params: {n/1e6:.1f}M")

    tc = train_loop.TrainConfig(
        steps=args.steps, ckpt_every=50, log_every=10,
        ckpt_dir=args.ckpt_dir, lr=3e-4, warmup=20)
    data = train_loop.synthetic_lm_data(cfg, args.batch, args.seq)
    result = train_loop.train(model, data, tc)
    h = result["history"]
    print(f"loss: first {h[0]:.3f} -> last {h[-1]:.3f} "
          f"({'DECREASED' if h[-1] < h[0] else 'did not decrease'})")


if __name__ == "__main__":
    main()
